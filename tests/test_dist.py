"""Distributed runtime tests on an 8-device simulated mesh.

jax locks the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# repro.dist is now the FL multi-host runtime (tests/test_dist_fl.py); the
# transformer mesh-TRAINING runtime these tests exercise is still absent
# from this checkout, so gate on its entry module specifically
pytest.importorskip("repro.dist.train_step")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=560) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, dataclasses, numpy as np
from repro.configs import base as cbase
from repro.dist.sharding import MeshLayout, make_plan
from repro.dist import train_step as train_lib
from repro.dist.collectives import MeshCompression
from repro.launch.mesh import make_mesh

def setup(arch="gemma2-2b", compression=True, scale_step=True, cpp=2):
    cfg = dataclasses.replace(cbase.get(arch).reduced(), dtype=jnp.float32)
    mesh = make_mesh((4, 2), ("data", "model"))
    layout = MeshLayout(1, 4, 2, clients_per_pod=cpp)
    plan = make_plan(cfg, 2)
    settings = train_lib.TrainSettings(
        microbatches=2, lr=1e-3,
        compression=MeshCompression(enabled=compression, block=64, sparsity=0.9),
        scale_step=scale_step)
    make, sds, sh, specs = train_lib.make_train_step(cfg, layout, plan, mesh, settings)
    B, S = 8, 64
    from repro.configs import make_inputs
    batch = make_inputs(jax.random.PRNGKey(1), cfg, B, S)
    batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    fn = make(batch_sds)
    batch_sh = train_lib.batch_shardings(cfg, layout, mesh, batch_sds)
    run = jax.jit(fn, in_shardings=(sh, batch_sh), out_shardings=(sh, None))
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, layout, plan, mesh, settings)
    return cfg, run, state, batch
"""


def test_train_step_learns_with_compression():
    out = run_sub(COMMON + """
cfg, run, state, batch = setup()
losses = []
for _ in range(6):
    state, m = run(state, batch)
    losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("OK", losses[0], losses[-1])
""")
    assert "OK" in out


def test_compressed_payload_smaller_than_dense():
    out = run_sub(COMMON + """
cfg, run, state, batch = setup(compression=True)
_, m1 = run(state, batch)
cfg, run2, state2, batch = setup(compression=False)
_, m2 = run2(state2, batch)
p_comp, p_dense = float(m1["payload_bytes"]), float(m2["payload_bytes"])
assert p_comp < p_dense / 4, (p_comp, p_dense)
print("OK", p_comp, p_dense)
""")
    assert "OK" in out


def test_moe_and_ssm_archs_train_on_mesh():
    out = run_sub(COMMON + """
for arch in ["mixtral-8x22b", "mamba2-370m"]:
    cfg, run, state, batch = setup(arch)
    losses = []
    for _ in range(3):
        state, m = run(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), (arch, losses)
    print("OK", arch, losses)
""")
    assert out.count("OK") == 2


def test_tp_equivalence_with_single_device():
    """The sharded forward must match the unsharded model numerically."""
    out = run_sub(COMMON + """
from repro.models import transformer
from repro.models.common import ShardCtx, UNSHARDED
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

cfg = dataclasses.replace(cbase.get("internlm2-1.8b").reduced(), dtype=jnp.float32)
mesh = make_mesh((1, 4), ("data", "model"))
plan4 = make_plan(cfg, 4)
# single-device params; re-init per shard deterministically is hard, so test
# the vocab-parallel loss against a replicated-weight equivalent at tp=4 with
# attn replicated for exactness.
B, S = 2, 64
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)

params1 = transformer.init_params(jax.random.PRNGKey(0), cfg, transformer.SINGLE)
loss1 = transformer.loss_fn(params1, {"tokens": tokens, "labels": labels},
                            cfg, transformer.SINGLE, UNSHARDED)

# build tp=4 params by SLICING the single-device params per shard
plan = make_plan(cfg, 4)
spec = cfg.attn_spec(4, plan.attn_replicated)
def shard_params(idx):
    import numpy as np
    p = jax.tree.map(lambda x: np.asarray(x), params1)
    out = {"final_ln": p["final_ln"]}
    vl = cfg.padded_vocab(4) // 4
    emb = np.zeros((cfg.padded_vocab(4), cfg.d_model), np.float32)
    emb[:cfg.vocab] = p["embed"][:cfg.vocab]
    out["embed"] = emb[idx*vl:(idx+1)*vl]
    layers = p["layers"]
    hl = spec.q_local
    hd = cfg.head_dim
    def sl(name, arr):
        if name == "wq":
            return arr.reshape(-1, cfg.n_heads, hd, cfg.d_model)[:, idx*hl:(idx+1)*hl].reshape(arr.shape[0], hl*hd, cfg.d_model)
        if name in ("wk", "wv"):
            if spec.kv_sharded:
                kvl = cfg.n_kv_heads // 4
                return arr.reshape(-1, cfg.n_kv_heads, hd, cfg.d_model)[:, idx*kvl:(idx+1)*kvl].reshape(arr.shape[0], kvl*hd, cfg.d_model)
            return arr
        if name == "wo":
            return arr.reshape(-1, cfg.d_model, cfg.n_heads, hd)[:, :, idx*hl:(idx+1)*hl].reshape(arr.shape[0], cfg.d_model, hl*hd)
        return arr
    ffl = cfg.d_ff // 4
    lay = {
        "ln1": layers["ln1"], "ln2": layers["ln2"],
        "attn": {k: sl(k, v) for k, v in layers["attn"].items()},
        "mlp": {"w_gate": layers["mlp"]["w_gate"][:, idx*ffl:(idx+1)*ffl],
                 "w_up": layers["mlp"]["w_up"][:, idx*ffl:(idx+1)*ffl],
                 "w_down": layers["mlp"]["w_down"][:, :, idx*ffl:(idx+1)*ffl]},
    }
    out["layers"] = lay
    return out

shards = [shard_params(i) for i in range(4)]
gparams = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)  # (4, ...) leading

ctx = ShardCtx(tp_axis="model", tp_size=4, attn_replicated=plan.attn_replicated,
               seq_parallel=True)

def per_chip(gp, tokens, labels):
    p = jax.tree.map(lambda x: x[0], gp)
    return transformer.loss_fn(p, {"tokens": tokens, "labels": labels}, cfg, plan, ctx)

loss4 = shard_map(per_chip, mesh=mesh,
                  in_specs=(P("model"), P(), P()), out_specs=P(),
                  check_rep=False)(gparams, tokens, labels)
print("loss1", float(loss1), "loss4", float(jnp.mean(loss4)))
np.testing.assert_allclose(float(loss1), float(jnp.mean(loss4)), rtol=2e-4)
print("OK tp-equivalence")
""")
    assert "OK tp-equivalence" in out


def test_decode_step_mesh_runs():
    out = run_sub(COMMON + """
from repro.dist import serve_step as serve_lib
cfg = dataclasses.replace(cbase.get("gemma2-2b").reduced(), dtype=jnp.float32)
mesh = make_mesh((4, 2), ("data", "model"))
layout = MeshLayout(1, 4, 2, clients_per_pod=2)
fn, in_sds, in_sh, plan = serve_lib.make_decode_step(cfg, layout, mesh, 8, 64)
(p_sds, c_sds, t_sds) = in_sds
(p_sh, c_sh, t_sh) = in_sh
run = jax.jit(fn, in_shardings=(p_sh[0], p_sh[1], c_sh, t_sh))
# concrete zero-init params/cache just to execute
import numpy as np
pz = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_sds[0])
sz = jax.tree.map(lambda s: jnp.ones(s.shape, s.dtype), p_sds[1])
cz = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), c_sds)
toks = jnp.zeros((8,), jnp.int32)
nxt, cache = run(pz, sz, cz, toks)
assert nxt.shape == (8,)
assert int(cache.pos) == 1
print("OK decode mesh")
""")
    assert "OK decode mesh" in out
