"""Beyond-paper coverage: non-IID dirichlet splits, long-horizon codec
behaviour, and FL protocol invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.coding import nnc
from repro.core import quant as quant_lib
from repro.data import federated, synthetic


def test_dirichlet_split_is_noniid():
    task = synthetic.ImageTask("n", 10, 3, prototypes_per_class=2)
    x, y = synthetic.make_image_dataset(jax.random.PRNGKey(0), task, 1200)
    iid = federated.split_federated(jax.random.PRNGKey(1), x, y, 4)
    nid = federated.split_federated(jax.random.PRNGKey(1), x, y, 4,
                                    dirichlet_alpha=0.1)

    def label_skew(splits):
        # max class-fraction per client, averaged: higher = more skewed
        out = []
        for c in range(splits.num_clients):
            labs = np.asarray(splits.client_y[c])
            frac = np.bincount(labs, minlength=10) / len(labs)
            out.append(frac.max())
        return float(np.mean(out))

    assert label_skew(nid) > label_skew(iid) + 0.1


def test_dirichlet_split_equal_client_sizes():
    task = synthetic.ImageTask("n", 10, 3, prototypes_per_class=2)
    x, y = synthetic.make_image_dataset(jax.random.PRNGKey(2), task, 800)
    s = federated.split_federated(jax.random.PRNGKey(3), x, y, 4,
                                  dirichlet_alpha=0.3)
    assert s.client_x.shape[0] == 4
    assert s.client_x.shape[1] == s.client_y.shape[1]


def test_codec_long_horizon_accumulated_updates():
    """Simulates many rounds of coded deltas: bytes stay bounded and the
    cumulative reconstruction matches the cumulative true signal exactly."""
    rng = np.random.default_rng(0)
    q = quant_lib.QuantConfig()
    total_true = np.zeros((64, 32), np.float64)
    total_recon = np.zeros((64, 32), np.float64)
    for r in range(10):
        delta = (rng.standard_normal((64, 32)) * 1e-3).astype(np.float32)
        delta[rng.random((64, 32)) < 0.9] = 0.0
        lv = quant_lib.quantize(jnp.asarray(delta), q.step_size)
        data = nnc.encode_tree({"w": np.asarray(lv)})
        back = nnc.decode_tree(data, nnc.shapes_of({"w": np.asarray(lv)}))
        recon = np.asarray(back["w"], np.float64) * q.step_size
        total_true += delta
        total_recon += recon
        assert len(data) < 64 * 32  # far below raw
    # only quantization error remains (codec is lossless)
    assert np.abs(total_true - total_recon).max() <= 10 * q.step_size / 2 + 1e-9


@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_server_average_invariant(num_clients, seed):
    """Mean of per-client reconstructions == what each client would compute
    from the decoded stream (aggregation is linear in the decoded levels)."""
    rng = np.random.default_rng(seed)
    q = quant_lib.QuantConfig()
    deltas = [jnp.asarray((rng.standard_normal(128) * 1e-3).astype(np.float32))
              for _ in range(num_clients)]
    levels = [quant_lib.quantize(d, q.step_size) for d in deltas]
    recons = [quant_lib.dequantize(l, q.step_size) for l in levels]
    mean_recon = np.mean([np.asarray(r) for r in recons], axis=0)
    # decode path
    decoded = []
    for l in levels:
        msg = nnc.encode_tree({"w": np.asarray(l)})
        back = nnc.decode_tree(msg, nnc.shapes_of({"w": np.asarray(l)}))
        decoded.append(np.asarray(back["w"], np.float32) * q.step_size)
    np.testing.assert_allclose(np.mean(decoded, axis=0), mean_recon,
                               rtol=1e-6, atol=1e-9)
