"""Behavioural contract of the population subsystem (repro.fl.population):

* store parity — the seed-pinned byte totals and accuracies (727/712,
  561/566, 3439/3429) reproduce through the ShardedLazyStore with shard
  sizes forced small enough that spill/reload actually happens, and
  memory-vs-sharded runs of small-K sync and async scenarios produce
  identical round records,
* store lifecycle — spill/reload round-trips, LRU high-water bound, cold
  clients served from the template, writable reloads,
* streaming sampling — deterministic, distinct, availability/weight/
  exclude-aware, never enumerates the population,
* traffic — counter-hashed determinism, device-class proportions,
  availability extremes, per-dispatch churn coins,
* channel — latency draws keyed per (client, round), independent of the
  advisory num_clients,
* adaptive dispatch window — per-call saving derived from
  BENCH_cohort.json, validation of the config axis.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import ChannelConfig, ChannelModel
from repro.core import prand
from repro.core.protocol import ProtocolConfig
from repro.data import federated, synthetic
from repro.fl import (AsyncConfig, EngineConfig, FederatedEngine,
                      InMemoryStore, SamplingConfig, ShardedLazyStore,
                      StoreConfig, TrafficConfig, TrafficModel,
                      VirtualPopulationView, make_view, run_simulation,
                      stream_cohort)
from repro.fl.async_buffer import load_call_saving
from repro.fl.population import DIURNAL_DEFAULT
from repro.models import cnn

# ------------------------------------------------------------- fixtures

_PINS = {
    "fsfl": dict(cfg=dict(method="sparse", fixed_sparsity=0.9),
                 up_bytes=[727, 712], acc=[0.166667, 0.208333]),
    "stc": dict(cfg=dict(method="ternary", error_feedback=True,
                         fixed_sparsity=0.9, structured=False),
                up_bytes=[561, 566], acc=None),
    "fedavg_nnc": dict(cfg=dict(method="none"),
                       up_bytes=[3439, 3429], acc=[0.25, 0.25]),
}


def _tiny_setting(num_clients):
    task = synthetic.ImageTask("t", num_classes=4, channels=3, size=32,
                               prototypes_per_class=2, noise=0.25)
    x, y = synthetic.make_image_dataset(jax.random.PRNGKey(0), task, 480)
    splits = federated.split_federated(jax.random.PRNGKey(1), x, y,
                                       num_clients=num_clients)
    model = cnn.make_vgg("vgg_tiny_comms", [8, 16], 4, 3,
                         dense_width=16, pool_after=(0, 1))
    return model, splits


@pytest.fixture(scope="module")
def tiny2():
    return _tiny_setting(2)


@pytest.fixture(scope="module")
def tiny4():
    return _tiny_setting(4)


def _template(key=0):
    """A small fake per-client persistent pytree."""
    k = jax.random.PRNGKey(key)
    return {"residual": jax.random.normal(k, (3, 4)).astype(jnp.float32),
            "step": jnp.zeros((), jnp.int32)}


def _rows(template, ids):
    """Distinct per-client rows derived from the ids (host numpy)."""
    ids = np.asarray(ids)
    return {
        "residual": (np.asarray(template["residual"])[None]
                     + ids[:, None, None].astype(np.float32)),
        "step": ids.astype(np.int32),
    }


# ------------------------------------------------------------- store units

def test_sharded_spill_reload_roundtrip():
    tpl = _template()
    store = ShardedLazyStore(tpl, 64, StoreConfig(
        backend="sharded", shard_size=4, max_hot_shards=2))
    ids = np.arange(0, 64, 2)  # touches all 16 shards -> forced spills
    store.scatter(ids, _rows(tpl, ids))
    stats = store.stats()
    assert stats["spills"] > 0 and stats["max_hot_seen"] <= 2
    got = store.gather(ids)  # reloads spilled shards through the LRU
    want = _rows(tpl, ids)
    np.testing.assert_array_equal(np.asarray(got["step"]), want["step"])
    np.testing.assert_allclose(np.asarray(got["residual"]),
                               want["residual"], rtol=0, atol=0)
    assert store.stats()["loads"] > 0
    store.close()


def test_sharded_reloaded_shards_are_writable():
    """Scatter into a shard that went to disk and came back — restored
    leaves must be writable copies, not msgpack buffer views."""
    tpl = _template()
    store = ShardedLazyStore(tpl, 32, StoreConfig(
        backend="sharded", shard_size=4, max_hot_shards=1))
    store.scatter([0], _rows(tpl, [0]))
    store.scatter([10], _rows(tpl, [10]))   # evicts shard 0 to disk
    store.scatter([1], _rows(tpl, [1]))     # reload shard 0, write in place
    got = store.gather([0, 1])
    np.testing.assert_array_equal(np.asarray(got["step"]), [0, 1])
    store.close()


def test_sharded_cold_clients_serve_template():
    tpl = _template()
    store = ShardedLazyStore(tpl, 1000, StoreConfig(
        backend="sharded", shard_size=8, max_hot_shards=2))
    got = store.gather([3, 977])
    for leaf, tleaf in zip(jax.tree.leaves(got), jax.tree.leaves(tpl)):
        for row in np.asarray(leaf):
            np.testing.assert_array_equal(row, np.asarray(tleaf))
    stats = store.stats()
    assert stats["cold_gathers"] == 2 and stats["materializations"] == 0
    store.close()


def test_memory_vs_sharded_random_op_sequence():
    """Same random gather/scatter sequence through both backends."""
    tpl = _template()
    mem = InMemoryStore(tpl, 48)
    shd = ShardedLazyStore(tpl, 48, StoreConfig(
        backend="sharded", shard_size=4, max_hot_shards=2))
    rng = np.random.default_rng(0)
    for _ in range(12):
        ids = rng.choice(48, size=5, replace=False)
        if rng.random() < 0.6:
            rows = _rows(tpl, ids + rng.integers(0, 100))
            mem.scatter(ids, rows)
            shd.scatter(ids, rows)
        a, b = mem.gather(ids), shd.gather(ids)
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert shd.stats()["spills"] > 0  # the sequence actually exercised disk
    shd.close()


# ------------------------------------------------------------- streaming

def test_stream_cohort_deterministic_and_distinct():
    a = stream_cohort(7, 3, 10**6, 32)
    b = stream_cohort(7, 3, 10**6, 32)
    np.testing.assert_array_equal(a, b)
    assert len(set(a.tolist())) == 32
    assert a.min() >= 0 and a.max() < 10**6
    c = stream_cohort(7, 4, 10**6, 32)
    assert set(a.tolist()) != set(c.tolist())


def test_stream_cohort_exclude_and_accept():
    busy = set(range(0, 10**5, 2))  # all even ids busy
    got = stream_cohort(1, 0, 10**5, 16, exclude=busy)
    assert all(g % 2 == 1 for g in got.tolist())
    avail = stream_cohort(1, 0, 10**5, 16,
                          accept_fn=lambda ids: np.asarray(ids) % 3 == 0)
    assert all(g % 3 == 0 for g in avail.tolist())


def test_stream_cohort_weight_bias():
    def weight_fn(ids):
        ids = np.asarray(ids)
        return np.where(ids < 500, 1.0, 0.02)  # favor the first 500 of 10^4
    hits = np.concatenate([
        stream_cohort(5, r, 10**4, 16, weight_fn=weight_fn)
        for r in range(20)])
    frac_low = np.mean(hits < 500)
    assert frac_low > 0.5  # 500/10^4 uniform would give ~5%


def test_stream_cohort_small_population_full_draw():
    got = stream_cohort(2, 0, 8, 8)
    assert sorted(got.tolist()) == list(range(8))


# ------------------------------------------------------------- traffic

def test_traffic_deterministic_and_bounded():
    tm = TrafficModel(TrafficConfig(diurnal=DIURNAL_DEFAULT, day_s=240.0,
                                    timezone_spread=0.3, availability=0.8,
                                    seed=11))
    ids = np.arange(64)
    r1, r2 = tm.rate(37.0, ids), tm.rate(37.0, ids)
    np.testing.assert_array_equal(r1, r2)
    assert (r1 >= 0).all() and (r1 <= 1).all()
    a1 = tm.available(ids, 37.0, round_idx=3)
    a2 = tm.available(ids, 37.0, round_idx=3)
    np.testing.assert_array_equal(a1, a2)
    # latency: per-client, deterministic, positive
    lats = [tm.latency(c) for c in range(8)]
    assert lats == [tm.latency(c) for c in range(8)]
    assert all(v > 0 for v in lats) and len(set(lats)) > 1


def test_traffic_device_class_proportions():
    tm = TrafficModel(TrafficConfig(seed=4))
    cls = tm.device_class(np.arange(20_000))
    fracs = np.bincount(cls, minlength=3) / 20_000
    for got, want in zip(fracs, [c.fraction for c in tm.cfg.classes]):
        assert abs(got - want) < 0.02
    np.testing.assert_array_equal(cls, tm.device_class(np.arange(20_000)))


def test_traffic_availability_extremes_and_churn():
    always = TrafficModel(TrafficConfig(availability=1.0, seed=1))
    assert always.available(np.arange(100), 0.0, 0).all()
    tm = TrafficModel(TrafficConfig(churn_rate=0.3, seed=9))
    coins = [tm.churned(5, seq) for seq in range(50)]
    assert coins == [tm.churned(5, seq) for seq in range(50)]
    assert any(coins) and not all(coins)
    no_churn = TrafficModel(TrafficConfig(churn_rate=0.0, seed=9))
    assert not any(no_churn.churned(c, 0) for c in range(100))


# ------------------------------------------------------------- channel

def test_channel_independent_of_num_clients():
    cfg = ChannelConfig(up_mbps=1.0, latency_s=0.1, latency_sigma=0.5,
                        bandwidth_sigma=0.4, seed=3)
    small, big = ChannelModel(cfg, 8), ChannelModel(cfg, 10**6)
    for c in [0, 3, 7]:
        assert small.up_time(c, 10_000, round_idx=2) == \
            big.up_time(c, 10_000, round_idx=2)


def test_channel_latency_keyed_per_client_round():
    cfg = ChannelConfig(up_mbps=1.0, latency_s=0.1, latency_sigma=0.5, seed=3)
    ch = ChannelModel(cfg, 8)
    a = ch.up_time(1, 10_000, round_idx=0)
    assert a == ch.up_time(1, 10_000, round_idx=0)   # deterministic
    assert a != ch.up_time(1, 10_000, round_idx=1)   # varies by round
    assert a != ch.up_time(2, 10_000, round_idx=0)   # varies by client
    # sigma=0 reproduces the legacy fixed latency exactly
    flat = ChannelModel(dataclasses.replace(cfg, latency_sigma=0.0), 8)
    base = 10_000 * 8 / (1.0e6 * flat._bw_factor(prand.TAG_BW_UP, 1))
    assert flat.up_time(1, 10_000, round_idx=5) == pytest.approx(
        base + 0.1)


# ------------------------------------------------------------- adaptive

def test_load_call_saving_from_bench_and_default(tmp_path):
    repo_bench = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_cohort.json")
    if os.path.exists(repo_bench):
        s = load_call_saving(repo_bench)
        assert 0 < s < 10.0
    assert load_call_saving(str(tmp_path / "missing.json"),
                            default=0.123) == 0.123


def test_adaptive_window_config_validation(tiny2):
    model, splits = tiny2
    with pytest.raises(ValueError):  # adaptive is an async-only axis
        EngineConfig(async_cfg=AsyncConfig(adaptive_window=True)).validate(2)
    with pytest.raises(ValueError):  # fixed + adaptive windows conflict
        EngineConfig(mode="async", async_cfg=AsyncConfig(
            adaptive_window=True, dispatch_window=0.5)).validate(2)


# ------------------------------------------------------------- virtual

def test_virtual_view_maps_into_base_shards(tiny2):
    _, splits = tiny2
    view = VirtualPopulationView(splits, 1000, seed=3)
    idx = np.array([0, 17, 999])
    base = view.base_index(idx)
    assert base.shape == (3,) and (base >= 0).all() and (base < 2).all()
    np.testing.assert_array_equal(base, view.base_index(idx))
    cx, cy, vx, vy = view.gather(idx)
    assert cx.shape[0] == 3 and cy.shape[0] == 3
    # make_view: population None or == num_clients stays a plain view
    assert make_view(splits, None).dense
    assert not make_view(splits, 1000).dense


# ------------------------------------------------------------- engine parity

@pytest.mark.parametrize("name", ["fsfl", "stc", "fedavg_nnc"])
def test_seed_pins_reproduce_through_sharded_store(tiny2, name):
    """Byte totals and accuracies pinned on the eager engine must
    reproduce when every client's state lives in the lazy store —
    shard_size=1, max_hot_shards=1 forces spill+reload every round."""
    model, splits = tiny2
    pin = _PINS[name]
    cfg = ProtocolConfig(name=name, batch_size=32, local_lr=2e-3,
                         **pin["cfg"])
    res = run_simulation(
        model, cfg, splits, 2, jax.random.PRNGKey(7),
        engine=EngineConfig(store=StoreConfig(
            backend="sharded", shard_size=1, max_hot_shards=1)))
    assert [r.up_bytes for r in res.records] == pin["up_bytes"]
    if pin["acc"] is not None:
        assert [round(r.test_acc, 6) for r in res.records] == pin["acc"]


def _records(res):
    return [(r.up_bytes, round(r.test_acc, 6), tuple(r.participants))
            for r in res.records]


def test_memory_vs_sharded_identical_sync_cohort(tiny4):
    model, splits = tiny4
    cfg = ProtocolConfig(name="eqs23", method="sparse", error_feedback=True,
                         fixed_sparsity=0.9, structured=False,
                         batch_size=32, local_lr=2e-3)
    runs = {}
    for backend in ("memory", "sharded"):
        res = run_simulation(
            model, cfg, splits, 3, jax.random.PRNGKey(5),
            engine=EngineConfig(
                sampling=SamplingConfig(cohort_size=2),
                store=StoreConfig(backend=backend, shard_size=1,
                                  max_hot_shards=1)))
        runs[backend] = _records(res)
    assert runs["memory"] == runs["sharded"]


def test_memory_vs_sharded_identical_async(tiny4):
    model, splits = tiny4
    cfg = ProtocolConfig(name="eqs23", method="sparse", error_feedback=True,
                         fixed_sparsity=0.9, structured=False,
                         batch_size=32, local_lr=2e-3)
    runs = {}
    for backend in ("memory", "sharded"):
        res = run_simulation(
            model, cfg, splits, 2, jax.random.PRNGKey(5),
            engine=EngineConfig(
                mode="async",
                async_cfg=AsyncConfig(buffer_size=2, concurrency=3),
                store=StoreConfig(backend=backend, shard_size=1,
                                  max_hot_shards=1)))
        runs[backend] = _records(res)
    assert runs["memory"] == runs["sharded"]


def test_population_run_end_to_end(tiny2):
    """A virtual population larger than the data shards streams cohorts
    through the lazy store; participants are virtual ids."""
    model, splits = tiny2
    cfg = ProtocolConfig(name="eqs23", method="sparse", error_feedback=True,
                         fixed_sparsity=0.9, structured=False,
                         batch_size=32, local_lr=2e-3)
    res = run_simulation(
        model, cfg, splits, 2, jax.random.PRNGKey(5),
        engine=EngineConfig(
            sampling=SamplingConfig(cohort_size=4),
            population=64,
            store=StoreConfig(backend="sharded", shard_size=4,
                              max_hot_shards=2),
            traffic=TrafficConfig(day_s=240.0, availability=0.9, seed=2)))
    assert len(res.records) == 2
    parts = {c for r in res.records for c in r.participants}
    assert len(parts) > 2 and max(parts) >= 2  # virtual ids beyond shards
    assert all(len(r.participants) == 4 for r in res.records)


# ------------------------------------------------- full churn / bench path


def test_async_full_churn_completes_as_all_drop(tiny2):
    """Regression: at churn_rate=1 every dispatch vanishes before its
    upload, and the async scheduler used to spin its pop-dispatch loop
    forever (``self.now`` advanced only on availability stalls, never on
    fully-churned windows).  The bounded retry now surfaces all-drop
    rounds and the run completes."""
    model, splits = tiny2
    cfg = ProtocolConfig(name="fsfl", method="sparse", fixed_sparsity=0.9,
                         batch_size=32, local_lr=2e-3)
    res = run_simulation(
        model, cfg, splits, 2, jax.random.PRNGKey(3),
        engine=EngineConfig(
            mode="async",
            async_cfg=AsyncConfig(buffer_size=2, concurrency=2),
            traffic=TrafficConfig(churn_rate=1.0, seed=5)))
    assert len(res.records) == 2
    assert all(r.participants == () for r in res.records)
    assert all(r.up_bytes == 0 and r.down_bytes == 0 for r in res.records)


def test_load_call_saving_env_override_and_marker_walk(tmp_path, monkeypatch):
    """REPRO_BENCH_DIR wins outright; without it the marker walk resolves
    the checkout root (the old code hard-coded four dirname hops, which
    breaks under any installed layout)."""
    import json

    import repro.fl.async_buffer as ab

    bench = {"async": {
        "concurrency": 4,
        "no_wire": {
            "serial_completions": {"steady_agg_s": 2.0},
            "windowed": {"steady_agg_s": 1.0, "batch_sizes": [2, 2]}}}}
    (tmp_path / "BENCH_cohort.json").write_text(json.dumps(bench))
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    # (2.0 - 1.0) / concurrency 4 / (1 - 1/mean_batch 2) = 0.5
    assert load_call_saving() == pytest.approx(0.5)

    monkeypatch.delenv("REPRO_BENCH_DIR")
    root = ab._bench_root()
    assert root is not None
    assert any(os.path.exists(os.path.join(root, m))
               for m in ("BENCH_cohort.json", "pyproject.toml"))


def test_load_call_saving_fallback_warns_once(tmp_path, monkeypatch):
    import warnings

    import repro.fl.async_buffer as ab

    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path / "nowhere"))
    monkeypatch.setattr(ab, "_FALLBACK_WARNED", False)
    with pytest.warns(RuntimeWarning, match="BENCH_cohort.json"):
        assert ab.load_call_saving(default=0.07) == 0.07
    with warnings.catch_warnings():  # one warning per process, then silent
        warnings.simplefilter("error")
        assert ab.load_call_saving(default=0.07) == 0.07
