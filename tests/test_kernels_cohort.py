"""Cohort-axis Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Separate from tests/test_kernels.py because that module needs the
hypothesis dev extra; the device-encode path's correctness must be
asserted in every environment, so this suite uses plain parametrize.

Tolerance contract (see kernels/README.md):
  * int8 codes / integer levels — the wire data — are asserted BITWISE,
  * float scales vs the pure-jnp oracle use rtol=1e-6 (Pallas-interpret
    `amax/127` can differ from eager jnp by 1 ulp),
  * kernel-vs-kernel (batched row vs per-client call) IS bitwise — that
    equivalence is what makes device-encoded payloads byte-identical,
  * the level_assign float carry allows atol=2e-7 (FMA contraction in
    `carried - lv * step`); the levels stay bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.delta_compress import (delta_apply, delta_compress,
                                          delta_compress_batch)
from repro.kernels.level_assign import level_assign


# ------------------------------------------------- ragged delta_compress

@pytest.mark.parametrize("n", [0, 5, 127, 128, 1000])
def test_delta_compress_ragged_shapes(n):
    """Non-block-multiple n pads device-side INSIDE the jitted wrapper
    (the (n,) API stays; scales keep the ceil(n/block) layout)."""
    d = (jax.random.normal(jax.random.PRNGKey(n + 1), (n,)) * 0.3
         if n else jnp.zeros((0,)))
    q, scales = delta_compress(d, 0.1, block=128, interpret=True)
    q_ref, s_ref = ref.delta_compress(d, 0.1, 128)
    assert q.shape == (n,) and scales.shape == (-(-n // 128),)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(s_ref),
                               rtol=1e-6)


def test_delta_compress_ragged_roundtrips_through_apply():
    """delta_apply accepts the same ragged n (pads, slices back)."""
    n = 777
    k = jax.random.PRNGKey(21)
    w = jax.random.normal(k, (n,))
    d = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 0.1
    q, scales = delta_compress(d, 0.0, block=128, interpret=True)
    out = delta_apply(w, q, scales, coef=1.0, block=128, interpret=True)
    deq = np.zeros(-(-n // 128) * 128, np.float32)
    deq[:n] = np.asarray(q, np.float32)
    deq = (deq.reshape(-1, 128) * np.asarray(scales)[:, None]).reshape(-1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w) + deq[:n],
                               rtol=1e-6)


# ------------------------------------------------- delta_compress_batch

@pytest.mark.parametrize("k", [1, 4, 8])
def test_delta_compress_batch_bitwise_vs_single(k):
    """The cohort (K, n) kernel must be BIT-identical per row to the
    per-client kernel — this equivalence is what makes the device encode
    payloads byte-equal to the host path."""
    n = 300  # ragged: exercises the in-wrapper pad on both paths
    d = jax.random.normal(jax.random.PRNGKey(k), (k, n)) * 0.3
    qb, sb = delta_compress_batch(d, 0.05, block=128, interpret=True)
    assert qb.shape == (k, n) and sb.shape == (k, -(-n // 128))
    for i in range(k):
        qi, si = delta_compress(d[i], 0.05, block=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(qb[i]), np.asarray(qi))
        np.testing.assert_array_equal(
            np.asarray(sb[i]).view(np.uint32),
            np.asarray(si).view(np.uint32))  # bitwise, not allclose


@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("n", [128, 512])
def test_delta_compress_batch_matches_ref(k, n):
    d = jax.random.normal(jax.random.PRNGKey(k * 7 + n), (k, n)) * 0.2
    qb, sb = delta_compress_batch(d, 0.1, block=128, interpret=True)
    q_ref, s_ref = ref.delta_compress_batch(d, 0.1, 128)
    np.testing.assert_array_equal(np.asarray(qb), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s_ref), rtol=1e-6)


def test_delta_compress_batch_empty():
    qb, sb = delta_compress_batch(jnp.zeros((3, 0)), 0.0, block=128,
                                  interpret=True)
    assert qb.shape == (3, 0) and sb.shape == (3, 0)


# ------------------------------------------------- level_assign

@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("n", [64, 257, 1024])
def test_level_assign_matches_ref(k, n):
    """Fused carry+sparsify+quantize vs the residual.py/quant.py chain:
    LEVELS (the wire data) are bitwise; the float carry may differ by FMA
    contraction in `carried - lv * step`."""
    key = jax.random.PRNGKey(k * 31 + n)
    d = jax.random.normal(key, (k, n)) * 1e-2
    r = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 1e-3
    step = 4.8828125e-4
    lv, carry = level_assign(d, r, 2e-3, step, interpret=True)
    lv_ref, c_ref = ref.level_assign(d, r, 2e-3, step)
    assert lv.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_ref))
    np.testing.assert_allclose(np.asarray(carry), np.asarray(c_ref),
                               atol=2e-7)


def test_level_assign_matches_core_chain():
    """Against the actual core modules the kernel fuses (Eq. 5 carry →
    threshold sparsify → uniform quantize)."""
    from repro.core import quant as quant_lib
    key = jax.random.PRNGKey(5)
    d = jax.random.normal(key, (3, 500)) * 1e-2
    r = jax.random.normal(jax.random.fold_in(key, 1), (3, 500)) * 1e-3
    theta, step = 2e-3, quant_lib.STEP_SIZE_UNI
    carried = d + r
    kept = jnp.where(jnp.abs(carried) >= theta, carried, 0.0)
    want_lv = quant_lib.quantize(kept, step)
    lv, carry = level_assign(d, r, theta, step, interpret=True)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(want_lv))
    np.testing.assert_allclose(np.asarray(carry),
                               np.asarray(carried - want_lv * step),
                               atol=2e-7)


def test_level_assign_clips_to_max_level():
    d = jnp.array([[1e6, -1e6, 0.0]])
    r = jnp.zeros((1, 3))
    lv, _ = level_assign(d, r, 0.0, 1e-4, max_level=7, interpret=True)
    np.testing.assert_array_equal(np.asarray(lv), [[7, -7, 0]])
