"""Behavioural contract of the streaming ingest stage (repro.fl.ingest):

* determinism — fold order is submission order whatever the worker count
  or chunk boundary, so threaded == inline == the gather-path weighted
  mean, bitwise, for every decode engine,
* O(1) memory — at no point do more than ``chunk`` decoded pytrees
  co-exist (``IngestStats.max_resident``), independent of cohort size,
* quarantine — a corrupt payload rejects ONE contribution with a typed
  :class:`RejectedPayload` record while the rest of the cohort aggregates,
* config — ``IngestConfig`` and the ``EngineConfig.ingest`` interactions
  fail at definition/construction time, not mid-round.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import comms
from repro.core import quant as quant_lib
from repro.fl import TreeAccumulator, weighted_mean_trees
from repro.fl.engine import EngineConfig
from repro.fl.ingest import (IngestConfig, RejectedPayload, StreamingIngest)

# ------------------------------------------------------------- fixtures


def _tree_of(fn, node):
    if isinstance(node, dict):
        return {k: _tree_of(fn, v) for k, v in node.items()}
    return fn(node)


_SHAPES = {"conv": {"w": (6, 4, 3, 3), "b": (6,)}, "fc": {"w": (5, 24)}}
_SCALE_SHAPES = {"s0": (6,), "s1": (5,)}


def _cohort(k, seed=0, version=1, with_scales=True):
    """K distinct encoded updates + the framing spec -> (payloads, spec,
    decoded gather trees)."""
    q = quant_lib.QuantConfig()
    fine = _tree_of(lambda s: len(s) < 2, _SHAPES)
    bn_t = ({"m": jax.ShapeDtypeStruct((7,), np.float32)}
            if version == 2 else None)
    spec = comms.WireSpec(
        params=_tree_of(lambda s: jax.ShapeDtypeStruct(s, np.float32),
                        _SHAPES),
        scales=(_tree_of(lambda s: jax.ShapeDtypeStruct(s, np.float32),
                         _SCALE_SHAPES) if with_scales else None),
        fine_mask=fine, step_size=q.step_size,
        fine_step_size=q.fine_step_size, bn=bn_t, version=version)
    codec = comms.get_codec("nnc-cabac")
    payloads = []
    for i in range(k):
        rng = np.random.default_rng(seed * 100 + i)
        lv = _tree_of(lambda s: (rng.integers(-9, 10, s)
                                 * (rng.random(s) < 0.35)).astype(np.int32),
                      _SHAPES)
        recon = jax.tree.map(
            lambda l, f: l.astype(np.float32)
            * np.float32(q.fine_step_size if f else q.step_size), lv, fine)
        s_lv = (_tree_of(lambda s: rng.integers(-3, 4, s).astype(np.int32),
                         _SCALE_SHAPES) if with_scales else None)
        s_recon = (jax.tree.map(lambda l: l.astype(np.float32)
                                * np.float32(q.fine_step_size), s_lv)
                   if with_scales else None)
        bn = ({"m": rng.normal(size=(7,)).astype(np.float32)}
              if version == 2 else None)
        upd = comms.ClientUpdate(lv, s_lv, recon, s_recon, bn=bn)
        payloads.append(codec.encode(upd, spec))
    decs = codec.decode_batch(payloads, spec)
    return codec, payloads, spec, decs


def _ingest_all(codec, payloads, spec, cfg, weights=None):
    ing = StreamingIngest(codec, spec, cfg)
    for i, p in enumerate(payloads):
        ing.submit(i, p, weight=1.0 if weights is None else weights[i])
    return ing.finish()


# ------------------------------------------------------------- determinism


def test_inline_fold_equals_gather_weighted_mean():
    """The ingest mean IS weighted_mean_trees over the decoded cohort in
    submission order — same accumulator, bit-for-bit."""
    codec, payloads, spec, decs = _cohort(6)
    w = [0.5, 1.0, 2.0, 0.25, 1.5, 0.75]
    res = _ingest_all(codec, payloads, spec, IngestConfig(chunk=4), w)
    assert res.accepted == 6 and not res.rejected
    assert res.weight_sum == pytest.approx(sum(w))
    gather = weighted_mean_trees([d.params for d in decs], np.array(w))
    for a, b in zip(jax.tree.leaves(res.delta_params),
                    jax.tree.leaves(gather)):
        np.testing.assert_array_equal(a, b)
    g_scales = weighted_mean_trees([d.scales for d in decs], np.array(w))
    for a, b in zip(jax.tree.leaves(res.delta_scales),
                    jax.tree.leaves(g_scales)):
        np.testing.assert_array_equal(a, b)


def test_threaded_equals_inline_bitwise():
    """Decode may run on workers; folds drain FIFO, so any (workers, chunk)
    shape produces the identical aggregate."""
    codec, payloads, spec, _ = _cohort(11, seed=3)
    w = list(np.linspace(0.3, 2.0, 11))
    base = _ingest_all(codec, payloads, spec, IngestConfig(chunk=5), w)
    for cfg in (IngestConfig(chunk=3, workers=2, queue_depth=6),
                IngestConfig(chunk=1, workers=3, queue_depth=4),
                IngestConfig(chunk=11, workers=1, queue_depth=11)):
        res = _ingest_all(codec, payloads, spec, cfg, w)
        assert res.accepted == 11
        for a, b in zip(jax.tree.leaves(base.delta_params),
                        jax.tree.leaves(res.delta_params)):
            np.testing.assert_array_equal(a, b)


def test_speculative_engine_folds_identically():
    codec, payloads, spec, _ = _cohort(5, seed=4)
    a = _ingest_all(codec, payloads, spec,
                    IngestConfig(decode_engine="vectorized"))
    b = _ingest_all(codec, payloads, spec,
                    IngestConfig(decode_engine="speculative"))
    for x, y in zip(jax.tree.leaves(a.delta_params),
                    jax.tree.leaves(b.delta_params)):
        np.testing.assert_array_equal(x, y)
    # the engine override copies the codec, never mutates the registry one
    assert comms.get_codec("nnc-cabac").decode_engine == "vectorized"


def test_bn_section_folds_under_schema_v2():
    codec, payloads, spec, decs = _cohort(4, seed=5, version=2,
                                          with_scales=False)
    w = [1.0, 0.5, 2.0, 1.5]
    res = _ingest_all(codec, payloads, spec, IngestConfig(chunk=2), w)
    g_bn = weighted_mean_trees([d.bn for d in decs], np.array(w))
    for a, b in zip(jax.tree.leaves(res.bn), jax.tree.leaves(g_bn)):
        np.testing.assert_array_equal(a, b)
    assert res.delta_scales is None        # no scales section on this spec


# ------------------------------------------------------------- O(1) memory


def test_resident_trees_bounded_by_chunk_not_cohort():
    codec, payloads, spec, _ = _cohort(24, seed=6)
    for cfg in (IngestConfig(chunk=4, queue_depth=8),
                IngestConfig(chunk=4, queue_depth=8, workers=2)):
        res = _ingest_all(codec, payloads, spec, cfg)
        assert res.accepted == 24
        assert res.stats.max_resident <= 4     # never O(K) decoded pytrees
    # and the result carries means, not per-client lists
    assert not isinstance(res.delta_params, (list, tuple))


def test_backpressure_bounds_the_queue():
    """A fast producer cannot outrun the decoder into unbounded pending
    state: submit blocks once queue_depth is exceeded."""
    codec, payloads, spec, _ = _cohort(16, seed=7)
    cfg = IngestConfig(chunk=2, queue_depth=4, workers=1)
    ing = StreamingIngest(codec, spec, cfg)
    for i, p in enumerate(payloads):
        ing.submit(i, p)
        assert ing._pending() <= cfg.queue_depth + cfg.chunk
    res = ing.finish()
    assert res.accepted == 16


# ------------------------------------------------------------- quarantine


def test_corrupt_payload_quarantined_rest_of_cohort_aggregates():
    """K=8 with one truncated payload: 7 aggregate, 1 typed reject."""
    codec, payloads, spec, decs = _cohort(8, seed=8)
    bad = list(payloads)
    bad[3] = bad[3][:-3]                       # truncation: deterministic
    res = _ingest_all(codec, bad, spec, IngestConfig(chunk=4))
    assert res.accepted == 7
    assert res.stats.rejected == 1
    [rej] = res.rejected
    assert isinstance(rej, RejectedPayload)
    assert rej.seq == 3 and rej.client == 3
    assert rej.nbytes == len(bad[3])
    assert "CorruptPayloadError" in rej.error
    keep = [d.params for i, d in enumerate(decs) if i != 3]
    gather = weighted_mean_trees(keep, np.ones(7))
    for a, b in zip(jax.tree.leaves(res.delta_params),
                    jax.tree.leaves(gather)):
        np.testing.assert_array_equal(a, b)


def test_header_corruption_quarantined_on_threaded_ingest():
    codec, payloads, spec, _ = _cohort(8, seed=9)
    bad = list(payloads)
    flipped = bytearray(bad[5])
    flipped[1] ^= 0xFF                         # length-header corruption
    bad[5] = bytes(flipped)
    res = _ingest_all(codec, bad, spec,
                      IngestConfig(chunk=3, workers=2, queue_depth=6))
    assert res.accepted == 7 and res.rejected[0].seq == 5


def test_all_rejected_returns_empty_means():
    codec, payloads, spec, _ = _cohort(2, seed=10)
    res = _ingest_all(codec, [p[:4] for p in payloads], spec, IngestConfig())
    assert res.accepted == 0 and len(res.rejected) == 2
    assert res.delta_params is None and res.bn is None
    assert res.weight_sum == 0.0


# ------------------------------------------------------------- accumulator


def test_tree_accumulator_k2_equal_weight_is_bitwise_jnp_mean():
    """The fold the sync seed pins ride on: for two equal-weight f32 trees
    the f64 single-pass mean is bit-identical to the stacked jnp.mean."""
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    a = {"w": rng.normal(size=(257,)).astype(np.float32),
         "b": {"x": rng.normal(size=(6, 9)).astype(np.float32)}}
    b = jax.tree.map(lambda l: (l * np.float32(-1.7)
                                + np.float32(0.3)).astype(np.float32), a)
    acc = TreeAccumulator()
    acc.add(a, 1.0)
    acc.add(b, 1.0)
    ref = jax.tree.map(
        lambda x, y: np.asarray(jnp.mean(jnp.stack([x, y]), axis=0)), a, b)
    for m, r in zip(jax.tree.leaves(acc.mean()), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(m, r)
    assert acc.count == 2 and acc.weight_sum == pytest.approx(2.0)


def test_tree_accumulator_weighted_mean_is_stable():
    """Single-pass f64 accumulation: on an adversarial cancellation mix
    (magnitudes ~1e8 hiding deltas ~1e-1) the running fold tracks the f64
    batch reference where a float32 accumulator would lose the signal."""
    rng = np.random.default_rng(2)
    k = 33
    big = np.float32(1e8)
    trees = [{"w": (big * (-1.0 if i % 2 else 1.0)
                    + rng.normal(scale=0.1, size=(128,))).astype(np.float32)}
             for i in range(k)]
    w = (rng.random(k) * 0.9 + 0.1)
    acc = TreeAccumulator()
    f32 = np.zeros(128, np.float32)
    for t, wi in zip(trees, w):
        acc.add(t, float(wi))
        f32 += np.float32(wi) * t["w"]
    ref = (np.sum([wi * t["w"].astype(np.float64)
                   for t, wi in zip(trees, w)], axis=0)
           / w.sum()).astype(np.float32)
    np.testing.assert_allclose(acc.mean()["w"], ref, rtol=1e-6, atol=1e-6)
    # the f32 running fold drifts by orders of magnitude more
    f32_err = np.abs(f32 / np.float32(w.sum()) - ref)
    f64_err = np.abs(acc.mean()["w"] - ref)
    assert f64_err.max() <= f32_err.max()


def test_weighted_mean_trees_host_path_equals_accumulator():
    """weighted_mean_trees over host trees IS the TreeAccumulator fold —
    the identity that makes gather and streaming bitwise-interchangeable."""
    rng = np.random.default_rng(3)
    trees = [{"w": rng.normal(size=(40,)).astype(np.float32)}
             for _ in range(5)]
    w = np.array([0.2, 1.0, 0.4, 2.0, 0.9])
    acc = TreeAccumulator()
    for t, wi in zip(trees, w):
        acc.add(t, float(wi))
    got = weighted_mean_trees(trees, w)
    np.testing.assert_array_equal(got["w"], acc.mean()["w"])


# ------------------------------------------------------------- config


def test_ingest_config_validation():
    with pytest.raises(ValueError, match="chunk"):
        IngestConfig(chunk=0).validate()
    with pytest.raises(ValueError, match="queue_depth"):
        IngestConfig(chunk=8, queue_depth=4).validate()
    with pytest.raises(ValueError, match="workers"):
        IngestConfig(workers=-1).validate()
    IngestConfig().validate()


def test_engine_config_ingest_interactions():
    with pytest.raises(ValueError, match="unknown ingest"):
        EngineConfig(ingest="firehose").validate()
    # streaming consumes real payloads: the no-wire fast path has none
    with pytest.raises(ValueError, match="measure_bytes"):
        EngineConfig(ingest="streaming", measure_bytes=False).validate()
    # decode parallelism lives in IngestConfig.workers, not the uplink pool
    with pytest.raises(ValueError, match="IngestConfig.workers"):
        EngineConfig(ingest="streaming", uplink_workers=2).validate()
    # ingest_opts without streaming is a silent no-op -> rejected
    with pytest.raises(ValueError, match="ingest_opts"):
        EngineConfig(ingest_opts=IngestConfig(chunk=4)).validate()
    EngineConfig(ingest="streaming",
                 ingest_opts=IngestConfig(chunk=4)).validate()


def test_streaming_ingest_is_single_use():
    codec, payloads, spec, _ = _cohort(2, seed=11)
    ing = StreamingIngest(codec, spec, IngestConfig())
    ing.submit(0, payloads[0])
    ing.finish()
    with pytest.raises(RuntimeError, match="single-use"):
        ing.submit(1, payloads[1])
    with pytest.raises(RuntimeError, match="already"):
        ing.finish()


def test_bad_engine_codec_pair_fails_at_construction():
    codec, _, spec, _ = _cohort(1, seed=12)
    with pytest.raises(ValueError):
        StreamingIngest(codec, spec, IngestConfig(decode_engine="warp"))
    # raw-fp32 has no engine choices: any non-default engine is rejected
    with pytest.raises((ValueError, NotImplementedError)):
        StreamingIngest(comms.get_codec("raw-fp32"), spec,
                        IngestConfig(decode_engine="speculative"))
